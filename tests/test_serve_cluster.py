"""Churn-aware serve plane: per-slot decode correctness, DES-driven
session migration, quarantine gateway proxying, generation restarts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.dht.des import LanDelay, SimNet
from repro.models import Model
from repro.runtime import Membership, ReplicaSupervisor
from repro.serve import Replica, Request, ServeCluster


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_smoke_config("qwen2.5-3b").with_overrides(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _membership(n, t):
    m = Membership(t_q=60.0, now=lambda: t[0])
    for i in range(n):
        m.request_join(f"10.3.0.{i}", 7000 + i)
    return m


def _requests(cfg, count, *, max_new=10, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(f"s{i}",
                    rng.integers(0, cfg.vocab, 4 + (i % 4) * 3,
                                 dtype=np.int32),
                    max_new_tokens=max_new)
            for i in range(count)]


def _reference_tokens(model, params, prompt, steps, max_len):
    """Reference model: one session alone, batch = 1, incremental decode."""
    cache = model.init_cache(1, max_len)
    logits, cache = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]}, cache)
    toks = [int(jnp.argmax(logits[0]))]
    dec = jax.jit(model.decode_step)
    length = len(prompt)
    for _ in range(steps - 1):
        logits, cache = dec(params, cache,
                            jnp.asarray([[toks[-1]]], jnp.int32),
                            jnp.asarray([length], jnp.int32))
        toks.append(int(jnp.argmax(logits[0])))
        length += 1
    return toks


# ---------------------------------------------------------------------------
# vectorized slot engine
# ---------------------------------------------------------------------------

def test_replica_mixed_lengths_decode_at_own_positions(smoke_model):
    """Slots with very different lengths must each decode at their OWN
    cache position (the old engine stepped everyone at lengths.max() and
    short sessions attended garbage)."""
    cfg, model, params = smoke_model
    rep = Replica(model, slots=4, max_len=48)
    rep.attach_params(params)
    rng = np.random.default_rng(3)
    prompts = {f"m{i}": rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for i, n in enumerate((3, 9, 17, 26))}
    got = {sid: [rep.admit(Request(sid, p))] for sid, p in prompts.items()}
    for _ in range(7):
        for sid, tok in rep.decode_round().items():
            got[sid].append(tok)
    for sid, p in prompts.items():
        want = _reference_tokens(model, params, p, 8, 48)
        assert got[sid] == want, f"{sid} diverged from reference model"


def test_replica_evict_zeroes_slot_state_and_reuses_slot(smoke_model):
    cfg, model, params = smoke_model
    rep = Replica(model, slots=2, max_len=32)
    rep.attach_params(params)
    rng = np.random.default_rng(1)
    rep.admit(Request("a", rng.integers(0, cfg.vocab, 20, dtype=np.int32)))
    rep.admit(Request("b", rng.integers(0, cfg.vocab, 4, dtype=np.int32)))
    slot_a = rep.sessions["a"]
    rep.evict("a")
    assert rep.lengths[slot_a] == 0 and rep.tokens[slot_a, 0] == 0
    assert not rep.active[slot_a]
    assert rep.num_free == 1
    # freed slot is reusable and the survivor still matches the reference
    rep.admit(Request("c", rng.integers(0, cfg.vocab, 5, dtype=np.int32)))
    assert rep.num_active == 2
    with pytest.raises(RuntimeError):
        rep.admit(Request("d", rng.integers(0, cfg.vocab, 4, dtype=np.int32)))


# ---------------------------------------------------------------------------
# churn-aware cluster (acceptance: kill a replica with >= 8 mixed-length
# sessions mid-decode; zero losses, per-slot-correct positions, identical
# next-token output on the replica_set successors)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_cluster_survives_replica_failure_mid_decode(smoke_model):
    cfg, model, params = smoke_model
    t = [0.0]
    m = _membership(5, t)
    cluster = ServeCluster(m, model, params, slots=16, max_len=64)
    reqs = _requests(cfg, 12, max_new=10)
    for r in reqs:
        cluster.submit(r)

    by_owner = {}
    for rec in cluster.sessions.values():
        by_owner.setdefault(rec.owner, []).append(rec)
    victim = max(by_owner, key=lambda o: len(by_owner[o]))
    assert len(by_owner[victim]) >= 8     # mixed-length victim load
    lens = {len(r.prompt) for r in by_owner[victim]}
    assert len(lens) > 1

    # DES-driven churn schedule: the failure fires from the event heap
    # while decode rounds are in flight.
    net = SimNet(LanDelay(), seed=1)
    net.schedule_at(3.0, lambda: m.fail(victim))
    survivors_expected = {
        rec.session_id: int(m.ring_state.replica_set(rec.key, 2)[1])
        for rec in by_owner[victim]}
    rounds = 0
    while cluster.live_sessions:
        net.run_until(net.now + 1.0)      # advance sim time, fire churn
        cluster.step()
        rounds += 1
        assert rounds < 64

    # zero losses: every session completed in full
    assert all(len(r.generated) == 10 for r in cluster.sessions.values())
    # exactly the victim's sessions migrated, to their replica_set
    # successor at failure time
    for rec in cluster.sessions.values():
        if rec.session_id in survivors_expected:
            assert rec.migrations >= 1
            assert rec.owner == survivors_expected[rec.session_id]
        else:
            assert rec.migrations == 0
    # identical next-token output vs the reference model, through the
    # migration boundary (per-slot-correct decode positions)
    for rec in cluster.sessions.values():
        want = _reference_tokens(model, params, rec.prompt, 10, 64)
        assert rec.generated == want, f"{rec.session_id} diverged"


def test_cluster_join_migrates_only_the_new_arc(smoke_model):
    cfg, model, params = smoke_model
    t = [0.0]
    m = _membership(6, t)
    cluster = ServeCluster(m, model, params, slots=16, max_len=64)
    for r in _requests(cfg, 10, max_new=8, seed=5):
        cluster.submit(r)
    before = {sid: rec.owner for sid, rec in cluster.sessions.items()}
    nid = m.request_join("10.3.7.7", 7777)
    for sid, rec in cluster.sessions.items():
        if rec.migrations:
            assert rec.owner == nid       # moved into the joiner's arc
        else:
            assert rec.owner == before[sid]
    cluster.run()
    for rec in cluster.sessions.values():
        want = _reference_tokens(model, params, rec.prompt, 8, 64)
        assert rec.generated == want


# ---------------------------------------------------------------------------
# quarantine gateways (paper §V)
# ---------------------------------------------------------------------------

def test_quarantined_node_proxies_but_never_owns(smoke_model):
    cfg, model, params = smoke_model
    t = [0.0]
    m = _membership(4, t)
    cluster = ServeCluster(m, model, params, slots=8, max_len=64)
    gw = m.request_join("10.9.9.9", 9999, preemptible=True)
    assert m.ring_state.is_quarantined(gw)

    reqs = _requests(cfg, 4, max_new=6, seed=9)
    for r in reqs:
        cluster.submit(r, via=gw)         # request lands on the gateway
    assert cluster.proxied[gw] == 4
    assert gw not in cluster.replicas     # gateway owns no device slab
    assert all(rec.owner != gw for rec in cluster.sessions.values())
    cluster.run()
    assert all(len(r.generated) == 6 for r in cluster.sessions.values())

    # after T_q the gateway is admitted and takes over its arc
    t[0] = 61.0
    assert m.poll_quarantine() == [gw]
    sid = next(f"n-{i}" for i in range(10_000)
               if cluster.router.route([f"n-{i}"])[0] == gw)
    rng = np.random.default_rng(11)
    cluster.submit(Request(sid, rng.integers(0, cfg.vocab, 5,
                                             dtype=np.int32), 4))
    assert cluster.sessions[sid].owner == gw
    cluster.run()


def test_quarantine_member_drains_sessions_to_successor(smoke_model):
    """An active member pushed back under the §V mask (straggler) keeps
    its device slab but loses ownership: its sessions migrate out."""
    cfg, model, params = smoke_model
    t = [0.0]
    m = _membership(5, t)
    cluster = ServeCluster(m, model, params, slots=16, max_len=64)
    for r in _requests(cfg, 10, max_new=8, seed=2):
        cluster.submit(r)
    by_owner = {}
    for rec in cluster.sessions.values():
        by_owner.setdefault(rec.owner, []).append(rec)
    straggler = max(by_owner, key=lambda o: len(by_owner[o]))
    assert m.quarantine_member(straggler)
    assert all(rec.owner != straggler
               for rec in cluster.sessions.values() if not rec.done)
    cluster.run()
    for rec in cluster.sessions.values():
        want = _reference_tokens(model, params, rec.prompt, 8, 64)
        assert rec.generated == want


# ---------------------------------------------------------------------------
# generation-driven replica restart
# ---------------------------------------------------------------------------

def test_rejoining_node_gets_fresh_replica(smoke_model):
    cfg, model, params = smoke_model
    t = [0.0]
    m = _membership(4, t)
    cluster = ServeCluster(m, model, params, slots=8, max_len=64)
    for r in _requests(cfg, 8, max_new=4, seed=7):
        cluster.submit(r)
    owners = {rec.owner for rec in cluster.sessions.values()}
    victim = next(iter(owners))
    old_rep = cluster.replicas[victim]
    info = m.nodes[victim]
    m.fail(victim)
    assert victim not in cluster.replicas
    m.admit(victim, info.addr)            # same node id re-enters the ring
    cluster.run()
    for r in _requests(cfg, 6, max_new=3, seed=13):
        cluster.submit(Request("re-" + r.session_id, r.prompt, 3))
    if victim in cluster.replicas:
        assert cluster.replicas[victim] is not old_rep
        assert cluster.replicas[victim].generation > old_rep.generation
    cluster.run()


def test_replica_supervisor_generations():
    t = [0.0]
    m = _membership(4, t)
    sup = ReplicaSupervisor(m)
    g0 = sup.stamp()
    nid = m.members()[0]
    info = m.nodes[nid]
    assert not sup.needs_restart(nid, g0)
    m.fail(nid)
    assert sup.needs_restart(nid, g0)     # state from before the crash
    m.admit(nid, info.addr)
    assert sup.needs_restart(nid, g0)
    assert not sup.needs_restart(nid, sup.stamp())
    other = m.members()[1]
    assert not sup.needs_restart(other, g0)   # never left: state valid


# ---------------------------------------------------------------------------
# decode-attention backend threading
# ---------------------------------------------------------------------------

def test_serve_path_decode_kernel_threading(smoke_model):
    """The decode_use_kernel flag threads from Model through the serve
    decode path to the Pallas kernel.  Auto (None) engages the kernel
    only where it compiles — on this (non-TPU) backend auto must keep
    the faster jnp reference path — and pinning True must run the kernel
    (interpret mode autodetected) with identical tokens."""
    from unittest import mock

    import repro.kernels.decode_attention.ops as dops
    from repro.kernels.backend import default_interpret
    from repro.kernels.decode_attention.kernel import BS

    assert default_interpret() == (jax.default_backend() != "tpu")
    cfg, model, params = smoke_model
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (3, 7)]

    def run(mdl):
        rep = Replica(mdl, slots=2, max_len=BS)
        rep.attach_params(params)
        got = {f"k{i}": [rep.admit(Request(f"k{i}", p))]
               for i, p in enumerate(prompts)}
        for _ in range(3):
            for sid, tok in rep.decode_round().items():
                got[sid].append(tok)
        return got

    calls = {"n": 0}
    orig = dops.decode_attention_pallas

    def spy(*a, **k):
        calls["n"] += 1
        return orig(*a, **k)

    with mock.patch.object(dops, "decode_attention_pallas", spy):
        auto = run(Model(cfg))
        if default_interpret():                # non-TPU: auto stays on ref
            assert calls["n"] == 0
        else:                                  # TPU: auto compiles the kernel
            assert calls["n"] > 0
        with_kernel = run(Model(cfg, decode_use_kernel=True))
        assert calls["n"] > 0
    without = run(Model(cfg, decode_use_kernel=False))
    assert auto == with_kernel == without


# ---------------------------------------------------------------------------
# review regressions: capacity spill, masked-member failure, slab reclaim,
# lockstep fallback for non-transformer families
# ---------------------------------------------------------------------------

def test_migration_spills_down_the_replica_set_when_successor_full(
        smoke_model):
    cfg, model, params = smoke_model
    t = [0.0]
    m = _membership(3, t)
    cluster = ServeCluster(m, model, params, slots=2, max_len=64,
                           replication=2)
    rng = np.random.default_rng(6)

    def sid_owned_by(node, start):
        return next(f"c{i}" for i in range(start, 100_000)
                    if cluster.router.route([f"c{i}"])[0] == node)

    nodes = sorted(m.members())
    a = cluster.router.route(["c0"])[0]
    # fill A with 2 sessions, and A's ring successor B with 2 of its own
    b = int(m.ring_state.succ(a, 1))
    i = 0
    for node in (a, a, b, b):
        sid = sid_owned_by(node, i)
        i = int(sid[1:]) + 1
        cluster.submit(Request(sid, rng.integers(0, cfg.vocab, 5,
                                                 dtype=np.int32), 6))
    assert cluster.replicas[b].num_free == 0
    m.fail(a)                              # B (primary successor) is full
    third = ({int(x) for x in m.members()} - {b})
    for rec in cluster.sessions.values():
        if rec.migrations:
            assert rec.owner in third      # spilled to replica_set[1]
    cluster.run()
    assert all(len(r.generated) == 6 for r in cluster.sessions.values())
    for rec in cluster.sessions.values():
        want = _reference_tokens(model, params, rec.prompt, 6, 64)
        assert rec.generated == want


def test_fail_of_masked_member_disseminates_leave():
    t = [0.0]
    m = Membership(t_q=60.0, now=lambda: t[0])
    for i in range(6):
        m.request_join(f"10.4.0.{i}", 7000 + i)
    nid = m.members()[2]
    kinds = []
    m.subscribe(lambda ev: kinds.append(ev.kind))
    assert m.quarantine_member(nid)
    events_after_mask = m._events_seen
    m.fail(nid)                            # dead gateway must not linger
    assert kinds == ["quarantine", "leave"]
    assert m._events_seen == events_after_mask + 1
    assert not m.ring_state.is_quarantined(nid)
    assert nid not in m.ring_state.all_ids()
    assert nid not in m.nodes


def test_quarantine_member_reclaims_the_replica_slab(smoke_model):
    cfg, model, params = smoke_model
    t = [0.0]
    m = _membership(5, t)
    cluster = ServeCluster(m, model, params, slots=16, max_len=64)
    for r in _requests(cfg, 10, max_new=8, seed=2):
        cluster.submit(r)
    owners = {rec.owner for rec in cluster.sessions.values()}
    straggler = next(iter(owners))
    assert straggler in cluster.replicas
    m.quarantine_member(straggler)
    assert straggler not in cluster.replicas   # slab reclaimed, not hoarded
    cluster.run()


@pytest.mark.slow
def test_replica_lockstep_fallback_for_ssm_family():
    """SSM/hybrid families take no per-slot index array; the replica must
    fall back to the lockstep decode the old engine used."""
    cfg = get_smoke_config("falcon-mamba-7b").with_overrides(dtype="float32")
    model = Model(cfg)
    assert not model.supports_per_slot_decode
    params = model.init(jax.random.PRNGKey(0))
    rep = Replica(model, slots=2, max_len=32)
    rep.attach_params(params)
    rng = np.random.default_rng(8)
    rep.admit(Request("x", rng.integers(0, cfg.vocab, 6, dtype=np.int32)))
    rep.admit(Request("y", rng.integers(0, cfg.vocab, 6, dtype=np.int32)))
    for _ in range(3):
        out = rep.decode_round()
        assert set(out) == {"x", "y"}
        assert all(0 <= v < cfg.vocab for v in out.values())


def test_rejected_admit_leaks_no_slot(smoke_model):
    cfg, model, params = smoke_model
    rep = Replica(model, slots=2, max_len=16)
    rep.attach_params(params)
    rng = np.random.default_rng(5)
    with pytest.raises(ValueError):
        rep.admit(Request("too-long",
                          rng.integers(0, cfg.vocab, 16, dtype=np.int32)))
    assert rep.sessions == {} and rep.num_free == 2
    assert rep.decode_round() == {}        # no phantom session decodes


def test_admit_prefill_failure_rolls_back_slot_allocation(smoke_model):
    """Regression (ISSUE 5): the slot was popped and the session
    registered BEFORE prefill ran, so a prefill failure (bad tokens,
    OOM) left a phantom session with ``active=False`` — and the next
    ``decode_round`` raised KeyError in ``row_of[slot]`` for every
    caller.  A failed admit must roll the allocation back completely."""
    cfg, model, params = smoke_model
    rep = Replica(model, slots=2, max_len=16)
    rep.attach_params(params)
    with pytest.raises(Exception):
        # bad tokens: len() passes validation, prefill's jnp.asarray
        # rejects the object dtype — the failure happens POST-allocation
        rep.admit(Request("phantom", np.array(["tok", "tok"], object)))
    assert rep.sessions == {}, "phantom session survived a failed admit"
    assert rep.num_free == 2, "failed admit leaked its slot"
    # the replica must still serve: healthy admit + decode round (the
    # pre-fix engine KeyError'd here for every live session)
    tok = rep.admit(Request("ok", np.arange(4, dtype=np.int32) % cfg.vocab))
    out = rep.decode_round()
    assert set(out) == {"ok"} and isinstance(tok, int)


def test_serve_path_latency_traces_breakdown(smoke_model):
    """Every completed session reports a queue+route+decode wall-clock
    breakdown, and the cluster aggregates them (request-latency plane
    §9: the serve path's leg of the measured experiment)."""
    cfg, model, params = smoke_model
    t = [0.0]
    m = _membership(4, t)
    cluster = ServeCluster(m, model, params, slots=4, max_len=48)
    for r in _requests(cfg, 6, max_new=4):
        cluster.submit(r)
    cluster.run()
    report = cluster.latency_report()
    assert report["completed"] == 6
    assert report["total_us_p50"] > 0 and report["decode_us_mean"] > 0
    for trace in cluster.traces.values():
        assert trace.done
        assert trace.decode_us > 0          # prefill + decode rounds
        assert trace.route_us >= 0 and trace.queue_us >= 0
        # parts are measured inside the submit->done window
        assert trace.total_us * 1.5 + 100.0 > trace.decode_us


def test_stranded_sessions_rehome_when_capacity_frees(smoke_model):
    """If every replica_set member is full at failure time, the affected
    sessions stay flagged (not silently stranded on the dead owner) and
    re-home on a later step once slots free up."""
    cfg, model, params = smoke_model
    t = [0.0]
    m = _membership(2, t)                  # 2 nodes: replica_set = both
    cluster = ServeCluster(m, model, params, slots=2, max_len=64,
                           replication=2)
    rng = np.random.default_rng(12)

    def sid_owned_by(node, start):
        return next(f"f{i}" for i in range(start, 100_000)
                    if cluster.router.route([f"f{i}"])[0] == node)

    a, b = cluster.router.route(["f0"])[0], None
    b = next(n for n in m.members() if n != a)
    i = 0
    sids = []
    for node, max_new in ((a, 8), (a, 8), (b, 2), (b, 2)):
        sid = sid_owned_by(node, i)
        i = int(sid[1:]) + 1
        sids.append(sid)
        cluster.submit(Request(sid, rng.integers(0, cfg.vocab, 5,
                                                 dtype=np.int32), max_new))
    m.fail(a)                              # b's 2 slots are occupied
    assert cluster.stranded >= 2           # deferred, not crashed
    a_sessions = [s for s in sids if cluster.sessions[s].owner == a]
    assert a_sessions                      # still pointing at dead owner
    cluster.run()                          # b's shorts finish -> re-home
    for sid in sids:
        rec = cluster.sessions[sid]
        assert len(rec.generated) == rec.max_new_tokens
        want = _reference_tokens(model, params, rec.prompt,
                                 rec.max_new_tokens, 64)
        assert rec.generated == want


def test_preemptible_rejoin_of_active_member_notifies_and_fail_disseminates():
    t = [0.0]
    m = Membership(t_q=60.0, now=lambda: t[0])
    for i in range(6):
        m.request_join(f"10.5.0.{i}", 7000 + i)
    nid = m.members()[1]
    kinds = []
    m.subscribe(lambda ev: kinds.append(ev.kind))
    # active member restarts as a spot instance: must re-mask LOUDLY
    addr = m.nodes[nid].addr
    assert m.request_join(addr[0], addr[1], preemptible=True) == nid
    assert kinds == ["quarantine"]
    assert nid not in m.members()
    # and its death must still disseminate a leave (its join did)
    m.fail(nid)
    assert kinds == ["quarantine", "leave"]
    assert nid not in m.nodes and nid not in m.ring_state.all_ids()


def test_stranded_session_rehomes_onto_its_rejoined_owner(smoke_model):
    """If a stranded session's dead owner re-enters the ring (fresh,
    empty slab), owner-id equality must not be mistaken for residency:
    the session re-admits onto the rejoined node and completes."""
    cfg, model, params = smoke_model
    t = [0.0]
    m = _membership(2, t)
    cluster = ServeCluster(m, model, params, slots=2, max_len=64,
                           replication=2)
    rng = np.random.default_rng(14)

    def sid_owned_by(node, start):
        return next(f"r{i}" for i in range(start, 100_000)
                    if cluster.router.route([f"r{i}"])[0] == node)

    a = cluster.router.route(["r0"])[0]
    b = next(n for n in m.members() if n != a)
    i, sids = 0, []
    for node in (a, b, b):                 # fill b; one session on a
        sid = sid_owned_by(node, i)
        i = int(sid[1:]) + 1
        sids.append(sid)
        cluster.submit(Request(sid, rng.integers(0, cfg.vocab, 5,
                                                 dtype=np.int32), 8))
    info = m.nodes[a]
    m.fail(a)                              # b full -> a's session strands
    assert cluster.stranded >= 1
    m.admit(a, info.addr)                  # same node id rejoins, empty
    cluster.run()                          # must re-admit, not skip
    for sid in sids:
        rec = cluster.sessions[sid]
        assert len(rec.generated) == 8
        want = _reference_tokens(model, params, rec.prompt, 8, 64)
        assert rec.generated == want
