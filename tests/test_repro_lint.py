"""repro-lint (RL001-RL005) + baseline ratchet + runtime sanitizer.

Each rule gets a positive fixture (must flag) and a clean twin (must
not); the ratchet tests pin the new/baselined/stale semantics; the CLI
tests pin the exit codes the CI gate relies on; the sanitizer tests
corrupt each invariant and expect ``SanitizeError``.
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import Baseline, run_lint
from repro.analysis import sanitize
from repro.analysis.metering import metered, meter_count, reset_meters

REPO = Path(__file__).resolve().parent.parent


def _write(root: Path, rel: str, code: str) -> Path:
    p = root / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(code)
    return p


def _rules_of(report):
    return sorted({f.rule for f in report.findings})


# ---------------------------------------------------------------------------
# rule fixtures: positive must flag, clean twin must not
# ---------------------------------------------------------------------------

RL001_BAD = """\
import jax
import jax.numpy as jnp

@jax.jit
def relu_branchy(x):
    if x > 0:
        return x
    return jnp.zeros_like(x)
"""

RL001_OK = """\
import jax
import jax.numpy as jnp
from functools import partial

@jax.jit
def relu(x):
    return jnp.where(x > 0, x, jnp.zeros_like(x))

@partial(jax.jit, static_argnames=("n",))
def tiled(x, n):
    if n > 4:                 # static arg: python branch is fine
        return x * 2
    return x

@jax.jit
def guarded(x, h0=None):
    if h0 is None:            # identity test on a maybe-tracer is fine
        return x
    return x + h0
"""

RL002_BAD = """\
import numpy as np
import jax.numpy as jnp

def upload(xs):
    n = len(xs)
    buf = np.zeros(n, np.int32)
    return jnp.asarray(buf)
"""

RL002_OK = """\
import numpy as np
import jax.numpy as jnp
from repro.kernels.autotune import shape_bucket

def upload(xs):
    n = shape_bucket(len(xs))
    buf = np.zeros(n, np.int32)
    return jnp.asarray(buf)

def upload_chunked(xs, c):
    padded = (len(xs) + c - 1) // c * c   # round-to-multiple idiom
    buf = np.zeros(padded, np.int32)
    return jnp.asarray(buf)
"""

RL003_BAD = """\
import numpy as np
import jax.numpy as jnp

def decode_round(cache, tokens):
    logits = jnp.ones((4, 8)) * tokens
    return np.asarray(logits)
"""

RL003_OK = """\
import numpy as np
import jax.numpy as jnp
from repro.analysis.metering import metered

def decode_round(cache, tokens):
    toks = jnp.argmax(jnp.ones((4, 8)) * tokens, axis=-1)
    # repro-lint: allow(RL003) the one mandatory per-round transfer
    return np.asarray(toks)

@metered
def calibrate(route):
    import jax
    jax.block_until_ready(route)
"""

RL004_REF_BAD = """\
from jax.experimental import pallas as pl

def oracle(x):
    return x
"""

RL005_BAD = """\
import random
from datetime import datetime

def jitter():
    return random.random() + datetime.now().timestamp()
"""

RL005_OK = """\
import random
import numpy as np

def jitter(seed):
    rng = random.Random(seed)
    return rng.random() + float(np.random.default_rng(seed).random())
"""


def test_rl001_flags_tracer_branch_and_spares_clean_twin(tmp_path):
    _write(tmp_path, "bad.py", RL001_BAD)
    rep = run_lint([tmp_path], root=tmp_path)
    assert _rules_of(rep) == ["RL001"]
    assert rep.findings[0].scope == "relu_branchy"
    _write(tmp_path, "bad.py", RL001_OK)
    assert run_lint([tmp_path], root=tmp_path).findings == []


def test_rl001_reaches_through_the_call_graph(tmp_path):
    _write(tmp_path, "deep.py", """\
import jax

def helper(x):
    while x.sum() > 0:
        x = x - 1
    return x

@jax.jit
def entry(x):
    return helper(x)
""")
    rep = run_lint([tmp_path], root=tmp_path)
    assert [f.rule for f in rep.findings] == ["RL001"]
    assert rep.findings[0].scope == "helper"


def test_rl002_flags_unbucketed_dynamic_shape(tmp_path):
    _write(tmp_path, "bad.py", RL002_BAD)
    rep = run_lint([tmp_path], root=tmp_path)
    assert _rules_of(rep) == ["RL002"]
    _write(tmp_path, "bad.py", RL002_OK)
    assert run_lint([tmp_path], root=tmp_path).findings == []


def test_rl002_flags_dynamic_scalar_into_static_argname(tmp_path):
    _write(tmp_path, "bad.py", """\
import jax
import jax.numpy as jnp
from functools import partial

@partial(jax.jit, static_argnames=("n",))
def kernel(x, n):
    return x[:4] * n

def caller(xs):
    m = len(xs)
    return kernel(jnp.ones(4), n=m)
""")
    rep = run_lint([tmp_path], root=tmp_path)
    assert _rules_of(rep) == ["RL002"]
    assert "static argname" in rep.findings[0].message


def test_rl003_flags_hot_path_sync_and_honours_allowlists(tmp_path):
    _write(tmp_path, "serve/hot.py", RL003_BAD)
    rep = run_lint([tmp_path], root=tmp_path)
    assert _rules_of(rep) == ["RL003"]
    assert rep.findings[0].scope == "decode_round"
    # same syncs under pragma + @metered: clean, but counted suppressed
    _write(tmp_path, "serve/hot.py", RL003_OK)
    rep = run_lint([tmp_path], root=tmp_path)
    assert rep.findings == []
    assert len(rep.suppressed) == 1
    # the SAME file outside serve/ is not hot-path at all
    _write(tmp_path, "serve/hot.py", "")
    _write(tmp_path, "offline.py", RL003_BAD)
    assert run_lint([tmp_path], root=tmp_path).findings == []


def test_rl004_kernel_contract(tmp_path):
    # missing ref.py
    _write(tmp_path, "kernels/foo/kernel.py",
           "from repro.kernels.autotune import tiles_for\n")
    _write(tmp_path, "kernels/foo/ops.py", "def op(x):\n    return x\n")
    rep = run_lint([tmp_path], root=tmp_path)
    assert any("missing" in f.message and f.rule == "RL004"
               for f in rep.findings)
    # pallas-importing ref.py
    _write(tmp_path, "kernels/foo/ref.py", RL004_REF_BAD)
    rep = run_lint([tmp_path], root=tmp_path)
    assert any("imports pallas" in f.message for f in rep.findings)
    # hard-coded tiles
    _write(tmp_path, "kernels/foo/ref.py", "def oracle(x):\n    return x\n")
    _write(tmp_path, "kernels/foo/kernel.py", "TILE = 128\n")
    rep = run_lint([tmp_path], root=tmp_path)
    assert any("tiles_for" in f.message for f in rep.findings)
    # complete, contract-clean triple
    _write(tmp_path, "kernels/foo/kernel.py",
           "from repro.kernels.autotune import tiles_for\n")
    assert run_lint([tmp_path], root=tmp_path).findings == []


def test_rl005_determinism_in_sim_planes(tmp_path):
    _write(tmp_path, "dht/node.py", RL005_BAD)
    rep = run_lint([tmp_path], root=tmp_path)
    assert {f.rule for f in rep.findings} == {"RL005"}
    assert len(rep.findings) == 2          # unseeded RNG + wall clock
    _write(tmp_path, "dht/node.py", RL005_OK)
    assert run_lint([tmp_path], root=tmp_path).findings == []
    # the same code OUTSIDE dht/-core/ is not a sim plane
    _write(tmp_path, "dht/node.py", "")
    _write(tmp_path, "tools/node.py", RL005_BAD)
    assert run_lint([tmp_path], root=tmp_path).findings == []


# ---------------------------------------------------------------------------
# baseline ratchet semantics
# ---------------------------------------------------------------------------

def test_baseline_new_fails_baselined_passes_fixed_prunes(tmp_path):
    _write(tmp_path, "dht/node.py", RL005_BAD)
    first = run_lint([tmp_path], root=tmp_path)
    bl = Baseline.from_findings(first.findings)

    # baselined: same findings pass the gate
    diff = bl.diff(first.findings)
    assert diff.ok and len(diff.baselined) == 2 and not diff.stale

    # new: an extra offender fails even though the legacy ones pass
    _write(tmp_path, "dht/other.py", RL005_BAD)
    diff = bl.diff(run_lint([tmp_path], root=tmp_path).findings)
    assert not diff.ok
    assert len(diff.new) == 2 and len(diff.baselined) == 2

    # fixed: offenders gone -> gate passes and entries go stale
    _write(tmp_path, "dht/node.py", RL005_OK)
    _write(tmp_path, "dht/other.py", "")
    diff = bl.diff(run_lint([tmp_path], root=tmp_path).findings)
    assert diff.ok and len(diff.stale) == 2

    # --update-baseline prunes: the ratchet only shrinks
    pruned = Baseline.from_findings(run_lint([tmp_path],
                                             root=tmp_path).findings)
    assert sum(pruned.counts.values()) == 0


def test_baseline_keys_are_line_independent(tmp_path):
    _write(tmp_path, "dht/node.py", RL005_BAD)
    bl = Baseline.from_findings(run_lint([tmp_path], root=tmp_path).findings)
    # shift every finding down 3 lines: still baselined, nothing new
    _write(tmp_path, "dht/node.py", "\n\n\n" + RL005_BAD)
    diff = bl.diff(run_lint([tmp_path], root=tmp_path).findings)
    assert diff.ok and not diff.stale


def test_baseline_save_load_roundtrip(tmp_path):
    _write(tmp_path, "dht/node.py", RL005_BAD)
    bl = Baseline.from_findings(run_lint([tmp_path], root=tmp_path).findings)
    bl.save(tmp_path / "baseline.json")
    assert Baseline.load(tmp_path / "baseline.json").counts == bl.counts
    assert Baseline.load(tmp_path / "missing.json").counts == {}


# ---------------------------------------------------------------------------
# CLI: the exact exit codes the CI gate scripts rely on
# ---------------------------------------------------------------------------

def _cli(*args, cwd=REPO):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=cwd, env=env, capture_output=True, text=True)


@pytest.mark.parametrize("rule,rel,code", [
    ("RL001", "bad.py", RL001_BAD),
    ("RL002", "bad.py", RL002_BAD),
    ("RL003", "serve/hot.py", RL003_BAD),
    ("RL004", "kernels/foo/ops.py", "def op(x):\n    return x\n"),
    ("RL005", "dht/node.py", RL005_BAD),
])
def test_cli_exits_nonzero_on_each_seeded_violation(tmp_path, rule, rel,
                                                    code):
    _write(tmp_path, rel, code)
    res = _cli(str(tmp_path), "--root", str(tmp_path), "--no-baseline")
    assert res.returncode == 1, res.stdout + res.stderr
    assert rule in res.stdout


def test_cli_exits_zero_on_the_committed_tree():
    """The committed tree must be clean against the committed baseline —
    this IS the CI static-analysis gate, run in-process by the suite so
    a PR can never land a new finding even if CI config regresses."""
    res = _cli()
    assert res.returncode == 0, res.stdout + res.stderr


def test_cli_update_baseline_writes_and_gate_then_passes(tmp_path):
    _write(tmp_path, "dht/node.py", RL005_BAD)
    bl = tmp_path / "bl.json"
    res = _cli(str(tmp_path), "--root", str(tmp_path),
               "--baseline", str(bl), "--update-baseline")
    assert res.returncode == 0 and bl.exists()
    res = _cli(str(tmp_path), "--root", str(tmp_path), "--baseline", str(bl))
    assert res.returncode == 0
    assert "2 baselined" in res.stdout


# ---------------------------------------------------------------------------
# metering decorator
# ---------------------------------------------------------------------------

def test_metered_counts_calls():
    reset_meters()

    @metered
    def probe(x):
        return x * 2

    assert probe(3) == 6 and probe(4) == 8
    assert meter_count(probe) == 2
    assert getattr(probe, "__repro_metered__", False)
    reset_meters()
    assert meter_count(probe) == 0


# ---------------------------------------------------------------------------
# runtime sanitizer: corrupt each invariant, expect SanitizeError
# ---------------------------------------------------------------------------

@pytest.fixture
def sanitized():
    owned = sanitize.install()     # False if conftest already installed
    yield
    if owned:
        sanitize.uninstall()


def _ring(n=16):
    from repro.core.ringstate import RingState
    return RingState(range(100, 100 + n))


def test_sanitizer_clean_ring_ops_pass(sanitized):
    st = _ring()
    st.add(7)
    st.set_quarantined(7, True)
    st.remove(7)
    assert sanitize.stats().get("ringstate", 0) >= 3
    import numpy as np
    out = st.lookup(np.asarray([5, 1000, 10**12], np.uint64))
    assert out.size == 3
    assert sanitize.stats().get("ringstate.lookup", 0) >= 1


def test_sanitizer_catches_unsorted_ring_slab(sanitized):
    st = _ring()
    st._ids[0], st._ids[1] = st._ids[1], st._ids[0]    # corrupt order
    with pytest.raises(sanitize.SanitizeError, match="sorted"):
        st.add(7)


def test_sanitizer_catches_version_regression(sanitized):
    st = _ring()
    st.active_version = st.version + 10                # corrupt monotone
    with pytest.raises(sanitize.SanitizeError, match="version"):
        st.add(7)


def test_sanitizer_catches_short_replica_group(sanitized):
    from repro.dht.data import BlockStore

    class ShortPolicy:
        def replica_group(self, state, key, r):
            return [int(state.active_ids()[0])]        # 1 < r copies

    st = _ring(8)
    store = BlockStore(st, replication=2, policy=ShortPolicy())
    with pytest.raises(sanitize.SanitizeError, match="placed on 1"):
        store.put("blk", b"payload")


def test_sanitizer_catches_tombstone_resurrection(sanitized):
    from repro.dht.data import BlockStore
    st = _ring(8)
    store = BlockStore(st, replication=2)
    store.put("keep", b"v1")
    key = store.key_of("keep")
    store._tombs[key] = 99                  # corrupt: placed AND buried
    with pytest.raises(sanitize.SanitizeError, match="tombstoned"):
        store.put("other", b"v2")


def test_sanitizer_clean_store_churn_passes(sanitized):
    from repro.dht.data import BlockStore
    st = _ring(8)
    store = BlockStore(st, replication=2)
    store.put("a", b"x" * 32)
    store.put("b", b"y" * 32)
    st.remove(int(store._placement[store.key_of("a")][0]))
    store.sync()
    store.remove("b")
    assert sanitize.stats().get("blockstore.sync", 0) >= 1
    assert sanitize.stats().get("blockstore.remove", 0) >= 1


def test_sanitizer_catches_replica_slot_leak(sanitized):
    import jax
    from repro.configs import get_smoke_config
    from repro.models import Model
    from repro.serve import Replica
    cfg = get_smoke_config("qwen2.5-3b").with_overrides(dtype="float32")
    model = Model(cfg)
    rep = Replica(model, slots=4, max_len=32)
    rep.attach_params(model.init(jax.random.PRNGKey(0)))
    rep._free.pop()                                    # leak a slot
    with pytest.raises(sanitize.SanitizeError, match="slot leak"):
        rep.evict("no-such-session")


def test_sanitizer_install_is_idempotent_and_reversible():
    from repro.core.ringstate import RingState
    pre = RingState.add
    owned = sanitize.install()
    try:
        assert getattr(RingState.add, "__repro_sanitized__", False)
        assert sanitize.install() is False             # second install: no-op
    finally:
        if owned:
            sanitize.uninstall()
            assert RingState.add is pre
