"""Two-level bucketized ring lookup (DESIGN.md §7): equivalence with the
bisect reference under adversarial id distributions, incremental device
maintenance under churn, and O(batches) upload traffic.

The hypothesis property tests skip when hypothesis is absent (the
runtime image bakes in jax + numpy only); the randomized and
deterministic tests below them always run and cover the same invariants
with fixed seeds.
"""
import numpy as np
import pytest

from repro.core.edra import Event
from repro.core.ringstate import _BUCKET_MIN_N, _BUCKET_ROW, RingState

RNG = np.random.default_rng(13)


def _oracle(state: RingState, keys: np.ndarray) -> np.ndarray:
    """bisect over the active view: successor (first id >= key), wrapping
    to the ring origin — the semantics every lookup path must match."""
    act = state.active_ids()
    return act[np.searchsorted(act, keys) % act.size]


def _check_all_paths(state: RingState, keys: np.ndarray) -> None:
    keys = np.asarray(keys, np.uint64)
    want = _oracle(state, keys)
    np.testing.assert_array_equal(
        state.lookup(keys, use_buckets=True), want)
    np.testing.assert_array_equal(
        state.lookup(keys, use_buckets=False), want)
    np.testing.assert_array_equal(state.lookup(keys), want)   # auto


def _boundary_keys(state: RingState) -> np.ndarray:
    """Every active id and both its ring neighbours (wraparound
    included): the exact points where successor ownership flips."""
    act = state.active_ids()
    one = np.uint64(1)
    return np.unique(np.concatenate(
        [act, act - one, act + one,
         np.array([0, 2**64 - 1], np.uint64)]))


def test_row_width_matches_kernel_constant():
    from repro.kernels.ring_lookup.kernel import BW
    assert _BUCKET_ROW == BW


# ---------------------------------------------------------------------------
# hypothesis properties
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HYP = True
except ImportError:                                   # pragma: no cover
    _HYP = False

if _HYP:
    u64 = st.integers(min_value=0, max_value=2**64 - 1)
    u32 = st.integers(min_value=0, max_value=2**32 - 1)

    uniform_ids = st.lists(u64, min_size=2, max_size=300, unique=True)
    # clustered hi-words: many ids share one of a handful of hi words, so
    # whole swaths of the ring land in the same radix partitions
    clustered_ids = st.builds(
        lambda his, los: list({(int(his[i % len(his)]) << 32) | int(l)
                               for i, l in enumerate(los)}),
        st.lists(u32, min_size=1, max_size=3),
        st.lists(u32, min_size=2, max_size=300, unique=True))
    any_ids = st.one_of(uniform_ids, clustered_ids)

    @settings(max_examples=25, deadline=None)
    @given(any_ids, st.lists(u64, min_size=1, max_size=200))
    def test_bucketized_matches_bisect(ids, keys):
        state = RingState(ids)
        _check_all_paths(state, np.array(keys, np.uint64))
        _check_all_paths(state, _boundary_keys(state))

    @settings(max_examples=15, deadline=None)
    @given(any_ids, st.data())
    def test_bucketized_matches_bisect_under_quarantine(ids, data):
        state = RingState(ids)
        masked = data.draw(st.lists(
            st.sampled_from(sorted(ids)), max_size=len(ids) - 1,
            unique=True))
        for pid in masked:
            state.set_quarantined(int(pid), True)
        keys = data.draw(st.lists(u64, min_size=1, max_size=100))
        _check_all_paths(state, np.array(keys, np.uint64))
        _check_all_paths(state, _boundary_keys(state))

    @settings(max_examples=10, deadline=None)
    @given(st.lists(st.tuples(st.lists(u64, min_size=0, max_size=20),
                              st.lists(u64, min_size=0, max_size=20)),
                    min_size=1, max_size=8),
           st.lists(u64, min_size=1, max_size=64))
    def test_churn_sequences_stay_consistent(batches, keys):
        """Randomized join/leave batches interleaved with lookups: every
        sync must land on the bisect answer, and the upload count must
        grow with the number of batches, never with n."""
        state = RingState(_rand_ids(256))
        keys = np.array(keys, np.uint64)
        state.lookup(keys, use_buckets=True)
        u0 = state.upload_count
        for i, (joins, leaves) in enumerate(batches):
            live = state.active_ids()
            evs = [Event(subject_id=int(p), kind="join", seq=i)
                   for p in joins]
            evs += [Event(subject_id=int(live[p % live.size]), kind="leave",
                          seq=i) for p in leaves]
            state.apply_events(evs)
            if len(state):
                _check_all_paths(state, keys)
        assert state.upload_count - u0 <= 3 * len(batches)


# ---------------------------------------------------------------------------
# always-run randomized + deterministic coverage of the same invariants
# ---------------------------------------------------------------------------

def _rand_ids(k: int) -> np.ndarray:
    x = np.unique(RNG.integers(0, 2**64, size=2 * k, dtype=np.uint64))[:k]
    assert x.size == k
    return x


@pytest.mark.parametrize("n", [1, 2, 50, 3000])
def test_forced_bucket_path_matches_bisect(n):
    state = RingState(_rand_ids(n))
    keys = RNG.integers(0, 2**64, size=512, dtype=np.uint64)
    _check_all_paths(state, keys)
    _check_all_paths(state, _boundary_keys(state))


def test_auto_dispatch_threshold():
    small = RingState(_rand_ids(_BUCKET_MIN_N - 1))
    small.lookup(RNG.integers(0, 2**64, size=8, dtype=np.uint64))
    assert not small.bucket_stats().get("enabled", False)
    big = RingState(_rand_ids(_BUCKET_MIN_N))
    big.lookup(RNG.integers(0, 2**64, size=8, dtype=np.uint64))
    assert big.bucket_stats()["valid"]


def test_all_equal_hi_words_fall_back_to_flat():
    """Ids differing only below the radix: no directory size can split
    them, so the index must invalidate and the flat scan must serve."""
    hi = np.uint64(0xDEADBEEF) << np.uint64(32)
    state = RingState(hi | np.arange(1, 4001, dtype=np.uint64))
    keys = np.concatenate([
        RNG.integers(0, 2**64, size=256, dtype=np.uint64),
        hi | np.arange(0, 4100, 7, dtype=np.uint64)])
    _check_all_paths(state, keys)
    assert state.bucket_stats()["valid"] is False


def test_escalation_splits_moderate_clustering():
    """Everything below one base-directory bucket bound, but separable
    with finer radix bits: the directory escalates instead of giving
    up."""
    ids = np.unique(RNG.integers(0, 1 << 58, size=400,
                                 dtype=np.uint64))[:300]
    state = RingState(ids)
    _check_all_paths(state, RNG.integers(0, 2**64, size=256,
                                         dtype=np.uint64))
    stats = state.bucket_stats()
    assert stats["valid"] and stats["buckets"] > 64


def test_quarantined_peers_never_returned():
    state = RingState(_rand_ids(2500))
    live = state.active_ids()
    masked = live[RNG.integers(0, live.size, size=400)]
    for pid in np.unique(masked):
        state.set_quarantined(int(pid), True)
    keys = np.concatenate([_boundary_keys(state),
                           np.asarray(masked, np.uint64)])
    _check_all_paths(state, keys)
    owners = state.lookup(keys, use_buckets=True)
    assert not np.isin(owners, np.unique(masked)).any()


def test_randomized_churn_uploads_scale_with_batches_not_n():
    rng = np.random.default_rng(99)     # local: accounting bounds must
    # not depend on how much of the module RNG earlier tests consumed

    def ids(k):
        return np.unique(rng.integers(0, 2**64, size=2 * k,
                                      dtype=np.uint64))[:k]

    state = RingState(ids(16384))
    keys = rng.integers(0, 2**64, size=300, dtype=np.uint64)
    np.testing.assert_array_equal(state.lookup(keys), _oracle(state, keys))
    u0, b0 = state.upload_count, state.upload_bytes
    batches, events = 12, 16
    row_bytes = _BUCKET_ROW * 8 + 4
    for i in range(batches):
        live = state.active_ids()
        evs = [Event(subject_id=int(p), kind="leave", seq=i)
               for p in live[rng.integers(0, live.size, size=events // 2)]]
        evs += [Event(subject_id=int(p), kind="join", seq=i)
                for p in ids(events // 2)]
        state.apply_events(evs)
        np.testing.assert_array_equal(state.lookup(keys),
                                      _oracle(state, keys))
    # exactly one delta sync per batch...
    assert state.upload_count - u0 == batches
    assert state.delta_uploads >= batches
    # ...each shipping O(events) rows (every event dirties at most its
    # own bucket plus a run of preceding pads), never the O(n) matrix
    stats = state.bucket_stats()
    assert state.upload_bytes - b0 <= batches * 4 * events * row_bytes
    assert state.upload_bytes - b0 < batches * stats["matrix_bytes"] // 8
    _check_all_paths(state, _boundary_keys(state))


def test_delta_sync_equals_full_rebuild():
    """After heavy churn, the scatter-maintained device rows must be
    bit-identical to a from-scratch materialization of the same view."""
    state = RingState(_rand_ids(3000))
    state.lookup(RNG.integers(0, 2**64, size=64, dtype=np.uint64))
    for i in range(6):
        live = state.active_ids()
        evs = [Event(subject_id=int(p), kind="leave", seq=i)
               for p in live[RNG.integers(0, live.size, size=40)]]
        evs += [Event(subject_id=int(p), kind="join", seq=i)
                for p in _rand_ids(40)]
        state.apply_events(evs)
        state.lookup(RNG.integers(0, 2**64, size=64, dtype=np.uint64))
    fresh = RingState(state.active_ids())
    fresh._enable_buckets()
    incr = state.device_bucket_table()
    scratch = fresh.device_bucket_table()
    assert incr is not None and scratch is not None
    if state.bucket_stats()["buckets"] == fresh.bucket_stats()["buckets"]:
        for a, b in zip(incr, scratch):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    else:   # directory sizes diverged (capacity history): compare answers
        keys = _boundary_keys(state)
        np.testing.assert_array_equal(
            state.lookup(keys, use_buckets=True),
            fresh.lookup(keys, use_buckets=True))


def test_empty_table_raises_lookup_error():
    with pytest.raises(LookupError, match="empty routing table"):
        RingState().lookup(np.array([1], np.uint64))


def test_flat_kernel_empty_table_raises_lookup_error():
    """Satellite guard: the 32-bit flat kernel surfaces LookupError, not
    a cryptic mod-by-zero, when the table is empty."""
    import jax.numpy as jnp

    from repro.kernels.ring_lookup.kernel import ring_lookup_pallas
    with pytest.raises(LookupError, match="empty routing table"):
        ring_lookup_pallas(jnp.zeros(4, jnp.uint32),
                           jnp.zeros(0, jnp.uint32))
