"""Property tests for EDRA Theorems 1 and 2 (paper §IV-B, §IV-F)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import edra
from repro.core.tuning import rho


@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=2, max_value=4096))
def test_theorem1_exactly_once(n):
    """Every peer acknowledges the event exactly once (Theorem 1)."""
    assert edra.acknowledged_exactly_once(n)


@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=2, max_value=100_000))
def test_theorem1_logarithmic_depth(n):
    """Max hop depth <= rho, average ack time bound rho*Theta/2."""
    offs = np.arange(n, dtype=np.uint64)
    depth = edra.ack_depth(offs)
    p = rho(n)
    assert int(depth.max()) <= p
    # avg acknowledge time in synchronous Theta units = mean depth <= rho/2
    assert float(depth.mean()) <= p / 2 + 1e-9


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=4, max_value=65536))
def test_theorem2_set_sizes(n):
    """|{peers whose events p acks with TTL >= l}| == 2^(rho-l) over a
    full 2^rho ring (Theorem 2; truncated rings can only be smaller)."""
    p = rho(n)
    full = 1 << p
    offs = np.arange(full, dtype=np.uint64)
    ttls = edra.ack_ttl(offs, full)
    # peer p acks the event of the subject at offset -i with TTL ttl(i)
    for l in range(0, p + 1):
        count = int((ttls >= l).sum())
        assert count == 2 ** (p - l), (n, l, count)


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=2, max_value=4096))
def test_tree_parent_depth_consistency(n):
    tree = edra.dissemination_tree(n)
    offs = tree["offset"]
    parent = tree["parent"]
    depth = tree["depth"]
    nz = offs > 0
    # each child is exactly one hop deeper than its parent
    assert (depth[nz] == depth[parent[nz]] + 1).all()
    # parents clear exactly the lowest set bit
    assert ((offs[nz] & (offs[nz] - 1)) == parent[nz]).all()


def test_forward_targets_respect_rule8():
    n = 10
    # reporter forwards with rho=4: targets 1,2,4,8 (all < n)
    t = edra.forward_targets(0, 4, n)
    assert [x[0] for x in t] == [8, 4, 2, 1]
    # offset 8 with ttl 3 would hit 8+2=10, 8+4=12 — discharged (Rule 8)
    t = edra.forward_targets(8, 3, n)
    assert [x[0] for x in t] == [9]


def test_event_buffer_rules():
    buf = edra.EventBuffer(rho=4)
    e_hi = edra.Event(subject_id=1, kind="leave", seq=1)
    e_lo = edra.Event(subject_id=2, kind="join", seq=2)
    assert buf.acknowledge(e_hi, 4)
    assert not buf.acknowledge(e_hi, 2)      # duplicate suppressed
    assert buf.acknowledge(e_lo, 1)
    out = buf.flush()
    # Rule 3: TTL=ttl events go into all messages with lower TTL
    assert e_hi in out[0] and e_hi in out[3]
    assert e_lo in out[0] and e_lo not in out[1]
    assert len(buf) == 0
