"""kernels.edra_tree: Pallas kernel == numpy reference == core.edra tree.

The kernel's tree coordinates (ttl / depth / parent / Rule-8 fan-out)
must match the pure-numpy EDRA machinery in repro.core.edra for EVERY
ring size — especially non-powers-of-two, where Rule-8 truncation and
rho = ceil(log2 n) interact.  Acknowledge times must match the numpy
``tree_math`` realization (same hash-derived phases and delays) and
respect the tree order (a child acks after its parent's flush).

Hypothesis drives the adversarial sweeps when available (see
requirements-dev.txt); fixed-seed sweeps below always run.
"""
import numpy as np
import pytest

jnp = pytest.importorskip("jax.numpy")

from repro.core import edra
from repro.kernels.edra_tree.ops import edra_tree
from repro.kernels.edra_tree.ref import tree_math

RNG = np.random.default_rng(7)


def _levels(n: int) -> int:
    return max(1, int(np.ceil(np.log2(max(n, 2)))))


def _pairs(n: int, extra_offsets=()):
    """Adversarial offset set: full ring when small, else boundaries +
    powers of two +- 1 + random fill."""
    if n <= 1024:
        offs = np.arange(n, dtype=np.uint32)
    else:
        pow2 = 1 << np.arange(_levels(n), dtype=np.uint32)
        cand = np.concatenate([
            np.array([0, 1, n - 1], np.uint32), pow2, pow2 - 1,
            np.minimum(pow2 + 1, n - 1),
            RNG.integers(0, n, 512).astype(np.uint32)])
        offs = np.unique(cand[cand < n])
    if len(extra_offsets):
        offs = np.unique(np.concatenate(
            [offs, np.asarray(extra_offsets, np.uint32)]))
    p = offs.size
    return {
        "offset": offs,
        "n": np.full(p, n, np.uint32),
        "reporter": RNG.integers(0, n, p).astype(np.uint32),
        "t_detect": RNG.uniform(0, 50, p).astype(np.float32),
        "event_key": RNG.integers(0, 2**32, p, dtype=np.uint64
                                  ).astype(np.uint32),
    }


def _run_both(args, **kw):
    ref = tree_math(np, args["offset"], args["n"], args["reporter"],
                    args["t_detect"], args["event_key"], **kw)
    got = edra_tree(*(jnp.asarray(args[k]) for k in
                      ("offset", "n", "reporter", "t_detect", "event_key")),
                    **kw)
    return ref, got


def _assert_tree_equiv(n: int, theta: float, fill_rate: float = 0.0):
    args = _pairs(n)
    kw = dict(levels=_levels(n), theta=theta, delta_avg=0.02, seed=5,
              fill_rate=fill_rate, e_cap=4.0)
    (a_r, ttl_r, d_r, p_r, s_r), (a_k, ttl_k, d_k, p_k, s_k) = \
        _run_both(args, **kw)
    offs64 = args["offset"].astype(np.uint64)
    # tree coordinates == the numpy EDRA machinery (core.edra)
    np.testing.assert_array_equal(ttl_r, edra.ack_ttl(offs64, n))
    np.testing.assert_array_equal(d_r, edra.ack_depth(offs64))
    np.testing.assert_array_equal(p_r.astype(np.int64),
                                  edra.parent_offset(offs64))
    # kernel == reference (exact ints, float32-tolerance ack)
    np.testing.assert_array_equal(np.asarray(ttl_k), ttl_r)
    np.testing.assert_array_equal(np.asarray(d_k), d_r)
    np.testing.assert_array_equal(np.asarray(p_k), p_r)
    np.testing.assert_array_equal(np.asarray(s_k), s_r)
    np.testing.assert_allclose(np.asarray(a_k), a_r, rtol=3e-5, atol=1e-3)
    # acks happen at/after detection, and after the parent chain starts
    assert (a_r >= args["t_detect"] - 1e-3).all()
    if n <= 1024:
        # Theorem 1 (exactly-once): Rule-8 fan-outs over the full ring
        # cover every non-reporter peer exactly once
        assert int(s_r.sum()) == n - 1


@pytest.mark.parametrize("n", [2, 3, 5, 48, 255, 256, 257, 1000, 1024,
                               12_345, 1_000_000])
def test_tree_equiv_sweep(n):
    _assert_tree_equiv(n, theta=7.5)


@pytest.mark.parametrize("n", [7, 500, 4096])
def test_tree_equiv_unbuffered_and_early_close(n):
    _assert_tree_equiv(n, theta=0.0)                  # 1h-Calot mode
    _assert_tree_equiv(n, theta=7.5, fill_rate=0.2)   # Eq IV.4 model


def test_ack_respects_tree_order():
    """Within one event, a child's ack is strictly after its parent's
    flush: with theta > 0 every hop adds at least the network delay, so
    ack(child) > ack(parent) whenever the chain is shared."""
    n = 512
    offs = np.arange(n, dtype=np.uint32)
    ones = np.ones(n, np.uint32)
    kw = dict(levels=_levels(n), theta=5.0, delta_avg=0.01, seed=1)
    ack, ttl, depth, parent, _ = tree_math(
        np, offs, ones * n, ones * 17, np.zeros(n, np.float32),
        ones * 0xABCD1234, **kw)
    # same event_key/reporter for every pair => shared ancestor chain
    assert (ack[1:] > ack[parent[1:].astype(np.int64)]).all()
    # Theorem 1 bound shape: depth-d peers ack after >= d flush waits
    assert ack[0] == 0.0


def test_no_recompile_across_event_batches():
    """Same pair-block shape, different data -> one jit trace (churn
    batches never re-specialize the kernel)."""
    traces = []
    for seed in (1, 2, 3):
        rng = np.random.default_rng(seed)
        p = 4096
        args = (rng.integers(0, 1000, p).astype(np.uint32),
                np.full(p, 1000, np.uint32),
                rng.integers(0, 1000, p).astype(np.uint32),
                rng.uniform(0, 10, p).astype(np.float32),
                rng.integers(0, 2**32, p, dtype=np.uint64
                             ).astype(np.uint32))
        edra_tree(*(jnp.asarray(a) for a in args),
                  levels=10, theta=3.0, delta_avg=0.02)
        traces.append(edra_tree._cache_size())
    assert traces[0] == traces[-1]


# ---------------------------------------------------------------------------
# hypothesis properties
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HYP = True
except ImportError:                                   # pragma: no cover
    _HYP = False


if _HYP:
    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(min_value=2, max_value=50_000),
           theta=st.sampled_from([0.0, 1.0, 9.7]),
           fill=st.sampled_from([0.0, 0.15]))
    def test_hypothesis_tree_equiv(n, theta, fill):
        _assert_tree_equiv(n, theta=theta, fill_rate=fill)

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(min_value=2, max_value=4096), data=st.data())
    def test_hypothesis_theorem1_fanout(n, data):
        """Sum of Rule-8 fan-outs over a full ring is exactly n-1 for
        ARBITRARY n (the exactly-once delivery of Theorem 1), and every
        offset's parent has a strictly smaller offset (tree acyclicity)."""
        offs = np.arange(n, dtype=np.uint32)
        args = {
            "offset": offs, "n": np.full(n, n, np.uint32),
            "reporter": np.full(
                n, data.draw(st.integers(0, n - 1)), np.uint32),
            "t_detect": np.zeros(n, np.float32),
            "event_key": np.full(
                n, data.draw(st.integers(0, 2**32 - 1)), np.uint32),
        }
        _, ttl, _, parent, sends = tree_math(
            np, args["offset"], args["n"], args["reporter"],
            args["t_detect"], args["event_key"],
            levels=_levels(n), theta=2.0, delta_avg=0.01)
        assert int(sends.sum()) == n - 1
        assert (parent[1:] < offs[1:]).all()
        assert parent[0] == 0 and ttl[0] == edra.ack_ttl(
            np.zeros(1, np.uint64), n)[0]
